"""repro.explore tests: sweep-grid construction (divisor clamping, dedup,
stable point ids), Pareto dominance/frontier properties (hypothesis when
available), calibration math on synthetic measurements, and one 2x2
end-to-end sweep on a tiny MLP with cache-hit accounting + record
round-trip asserted."""

import json
import math

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import resource_model
from repro.core.folding import Folding, divisors
from repro.core.ir import Node
from repro.explore import (
    ExploreConfig,
    LayerShape,
    PARETO_MAXIMIZE,
    PARETO_MINIMIZE,
    clamp_folding,
    dominates,
    explore,
    load_record,
    pareto_front,
    sweep_grid,
)


def _mlp_graph(dims=(24, 16, 8), bits=2, seed=3):
    rng = np.random.default_rng(seed)
    g = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(dims) - 2:
            g.append(Node("quant_act", f"act{i}", {"bits": bits, "act_scale": 1.0}))
    return g


SHAPES = [LayerShape("fc0.mvu", 16, 24, 1), LayerShape("fc1.mvu", 8, 16, 1)]


# ------------------------------------------------------------------- grid
def test_clamp_folding_largest_divisor_at_or_under_target():
    f = clamp_folding(16, 24, 5, 9)
    assert f == Folding(4, 8)  # divisors(16) <= 5 -> 4; divisors(24) <= 9 -> 8
    assert clamp_folding(16, 24, 1, 1) == Folding(1, 1)
    # targets beyond the layer cap at the full dimension
    assert clamp_folding(16, 24, 999, 999) == Folding(16, 24)


def test_sweep_grid_points_are_legal_and_deduplicated():
    pts = sweep_grid(SHAPES, (1, 4, 16), (1, 8, 24))
    assert pts, "grid must not be empty"
    seen = set()
    for pt in pts:
        assert len(pt.foldings) == len(SHAPES)
        for shape, fold in zip(SHAPES, pt.foldings):
            assert shape.n % fold.pe == 0
            assert shape.k % fold.simd == 0
            assert fold.pe in divisors(shape.n)
        key = tuple((f.pe, f.simd) for f in pt.foldings)
        assert key not in seen, "duplicate realized design survived dedup"
        seen.add(key)


def test_sweep_grid_dedup_keeps_first_coordinate_id():
    # both 16 and 999 clamp to the same full-size folding on every layer:
    # the first grid coordinate must own the merged point
    pts = sweep_grid(SHAPES, (16, 999), (24, 999))
    ids = [p.point_id for p in pts]
    assert "pe16_simd24" in ids
    assert not any("999" in i for i in ids)


def test_sweep_grid_default_axes_cover_small_and_full_designs():
    pts = sweep_grid(SHAPES)
    folds = {tuple((f.pe, f.simd) for f in p.foldings) for p in pts}
    assert ((1, 1), (1, 1)) in folds  # fully folded corner
    assert ((16, 24), (8, 16)) in folds  # fully unfolded corner


def test_sweep_grid_empty_shapes_raises():
    with pytest.raises(ValueError):
        sweep_grid([])


# ----------------------------------------------------------------- pareto
def test_dominates_requires_strict_improvement():
    a = {"samples_per_s": 10.0, "lut_bytes": 5}
    assert not dominates(a, dict(a), maximize=("samples_per_s",),
                         minimize=("lut_bytes",))
    b = {"samples_per_s": 10.0, "lut_bytes": 6}
    assert dominates(a, b, maximize=("samples_per_s",), minimize=("lut_bytes",))
    assert not dominates(b, a, maximize=("samples_per_s",),
                         minimize=("lut_bytes",))


def test_pareto_front_drops_dominated_keeps_duplicates():
    pts = [
        {"samples_per_s": 10.0, "lut_bytes": 5},   # frontier
        {"samples_per_s": 10.0, "lut_bytes": 5},   # exact duplicate: kept
        {"samples_per_s": 9.0, "lut_bytes": 6},    # dominated by both
        {"samples_per_s": 20.0, "lut_bytes": 50},  # frontier (fast, big)
    ]
    front = pareto_front(pts, maximize=("samples_per_s",),
                         minimize=("lut_bytes",))
    assert front == [0, 1, 3]


def test_pareto_missing_key_is_worst_case():
    good = {"samples_per_s": 1.0, "lut_bytes": 1}
    hole = {"lut_bytes": 1}
    assert dominates(good, hole, maximize=("samples_per_s",),
                     minimize=("lut_bytes",))
    front = pareto_front([good, hole], maximize=("samples_per_s",),
                         minimize=("lut_bytes",))
    assert front == [0]


def test_pareto_front_property_no_member_dominated():
    # deterministic pseudo-random clouds; hypothesis variant below
    rng = np.random.default_rng(7)
    for _ in range(20):
        pts = [{"samples_per_s": float(rng.integers(1, 50)),
                "lut_bytes": float(rng.integers(1, 50)),
                "ff_bytes": float(rng.integers(1, 50))}
               for _ in range(rng.integers(1, 30))]
        front = pareto_front(pts, maximize=("samples_per_s",),
                             minimize=("lut_bytes", "ff_bytes"))
        assert front  # non-empty input -> non-empty frontier
        members = set(front)
        for i in front:
            assert not any(dominates(pts[j], pts[i],
                                     maximize=("samples_per_s",),
                                     minimize=("lut_bytes", "ff_bytes"))
                           for j in range(len(pts)))
        # every non-member is dominated by some frontier member
        for i, p in enumerate(pts):
            if i not in members:
                assert any(dominates(pts[j], p,
                                     maximize=("samples_per_s",),
                                     minimize=("lut_bytes", "ff_bytes"))
                           for j in front)


def test_pareto_front_hypothesis_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    point = st.fixed_dictionaries({
        "samples_per_s": st.integers(0, 8).map(float),
        "lut_bytes": st.integers(0, 8).map(float),
    })

    @hyp.given(st.lists(point, min_size=1, max_size=24))
    @hyp.settings(deadline=None, max_examples=80)
    def prop(pts):
        front = pareto_front(pts, maximize=("samples_per_s",),
                             minimize=("lut_bytes",))
        assert front == sorted(front)
        assert front
        for i in front:
            assert not any(dominates(pts[j], pts[i],
                                     maximize=("samples_per_s",),
                                     minimize=("lut_bytes",))
                           for j in range(len(pts)))
        for i in range(len(pts)):
            if i not in front:
                assert any(dominates(pts[j], pts[i],
                                     maximize=("samples_per_s",),
                                     minimize=("lut_bytes",))
                           for j in front)

    prop()


# ------------------------------------------------------------ calibration
def test_fit_cycle_time_recovers_exact_linear_data():
    cycles = [1, 10, 100, 1000]
    s = 2.5e-7
    seconds = [c * s for c in cycles]
    fit = resource_model.fit_cycle_time(cycles, seconds)
    assert math.isclose(fit, s, rel_tol=1e-12)
    errors = resource_model.cycle_model_errors(cycles, seconds)
    assert all(abs(e) < 1e-9 for e in errors)
    summary = resource_model.error_summary(errors)
    assert summary["n"] == 4
    assert summary["p90_abs"] < 1e-9


def test_fit_cycle_time_is_least_squares_not_mean_of_ratios():
    # one large-cycle point with slope 2, one tiny point with slope 1000:
    # least squares must follow the large point (sum(c*m)/sum(c^2)),
    # not average the per-point ratios
    cycles = [1000, 1]
    seconds = [2000.0, 1000.0]
    fit = resource_model.fit_cycle_time(cycles, seconds)
    expected = (1000 * 2000.0 + 1 * 1000.0) / (1000**2 + 1)
    assert math.isclose(fit, expected, rel_tol=1e-12)
    assert abs(fit - 2.0) < 0.01  # dominated by the big point


def test_cycle_model_errors_signed_and_summary_percentiles():
    # predicted = c * 1.0; measured chosen for exact signed errors
    cycles = [1, 1, 1, 1]
    seconds = [0.5, 1.0, 2.0, 4.0]  # errors: +1.0, 0.0, -0.5, -0.75
    errors = resource_model.cycle_model_errors(cycles, seconds, s_per_cycle=1.0)
    assert errors == pytest.approx([1.0, 0.0, -0.5, -0.75])
    summary = resource_model.error_summary(errors)
    assert summary["max_abs"] == pytest.approx(1.0)
    assert summary["mean_signed"] == pytest.approx((1.0 - 0.5 - 0.75) / 4)
    assert 0.0 < summary["p50_abs"] <= 1.0


def test_fit_cycle_time_rejects_degenerate_input():
    with pytest.raises(ValueError):
        resource_model.fit_cycle_time([], [])
    with pytest.raises(ValueError):
        resource_model.fit_cycle_time([1, 2], [1.0])
    with pytest.raises(ValueError):
        resource_model.cycle_model_errors([1], [0.0], s_per_cycle=1.0)


# ------------------------------------------------------------- end-to-end
@pytest.fixture(scope="module")
def small_sweep(tmp_path_factory):
    out = tmp_path_factory.mktemp("explore")
    cfg = ExploreConfig(
        graph=_mlp_graph(), name="tiny",
        build_overrides=dict(mode="standard", weight_bits=4, act_bits=2),
        pe_targets=(1, 8), simd_targets=(1, 16),
        packings=(False,),  # folding-only sweep: the legacy record shape
        batch=16, reps=1, out_dir=str(out),
        tune_kwargs={"reps": 1, "max_measure": 1, "sample_m": 16},
    )
    return explore(cfg)


def test_explore_sweep_points_bit_exact_and_pareto(small_sweep):
    rec = small_sweep
    assert rec["n_points"] == len(rec["points"]) == 4  # 2x2, no collapses
    assert rec["bit_exact"] is True
    ids = {p["point_id"] for p in rec["points"]}
    assert ids == {"pe1_simd1", "pe1_simd16", "pe8_simd1", "pe8_simd16"}
    front = set(rec["pareto_front"])
    assert front <= ids and front
    for p in rec["points"]:
        assert p["pareto"] == (p["point_id"] in front)
        assert p["interval_cycles"] >= 1
        assert p["samples_per_s"] > 0
        for key in PARETO_MAXIMIZE + PARETO_MINIMIZE:
            assert key in p
    # the folding axis survived the sweep: the fully-folded point runs more
    # cycles than the unfolded one (tune="off" keeps foldings distinct)
    by_id = {p["point_id"]: p for p in rec["points"]}
    assert (by_id["pe1_simd1"]["interval_cycles"]
            > by_id["pe8_simd16"]["interval_cycles"])
    assert by_id["pe1_simd1"]["lut_bytes"] <= by_id["pe8_simd16"]["lut_bytes"]


def test_explore_calibration_attached_and_gated(small_sweep):
    rec = small_sweep
    cal = rec["calibration"]
    assert cal["s_per_cycle"] > 0
    assert cal["samples"] == sum(len(p["nodes"]) for p in rec["points"])
    assert set(cal["per_node"]) == {"fc0.mvu", "fc1.mvu"}
    for p in rec["points"]:
        for node in p["nodes"]:
            assert node["predicted_s"] == pytest.approx(
                node["cycles"] * cal["s_per_cycle"])
            assert node["model_error"] is not None
    # gate contract: ceiling committed alongside the measured value
    assert rec["ceiling_only"] == ["model_error_p90"]
    assert rec["model_error_p90"] == pytest.approx(cal["summary"]["p90_abs"])
    assert rec["max_model_error_p90"] >= rec["model_error_p90"] + 0.5


def test_explore_cache_phase_hit_accounting(small_sweep):
    cache = small_sweep["cache"]
    n_mvu = 2  # fc0.mvu, fc1.mvu
    assert cache["cold_misses"] == n_mvu  # empty cache: every node measured
    assert cache["warm_hits"] == n_mvu  # warm replay: pure lookup
    assert cache["warm_misses"] == 0
    assert cache["cold_wall_s"] > 0 and cache["warm_wall_s"] > 0
    assert small_sweep["floor_only"] == ["cache_speedup"]
    assert small_sweep["cache_speedup"] == pytest.approx(
        cache["cold_wall_s"] / cache["warm_wall_s"])


def test_explore_packing_axis_doubles_grid_and_is_gated():
    """The default packings=(False, True) crosses the weight-storage axis
    into the grid: packed twins carry smaller weight bytes at equal
    folding, land on the frontier, and the record gains the floor gate."""
    cfg = ExploreConfig(
        graph=_mlp_graph(), name="tiny_packed",
        build_overrides=dict(mode="binary", weight_bits=1, act_bits=2),
        pe_targets=(1,), simd_targets=(1, 16),
        batch=16, reps=1,
        tune_kwargs={"reps": 1, "max_measure": 1, "sample_m": 16},
    )
    rec = explore(cfg)
    assert rec["n_points"] == len(rec["points"]) == 4  # 1x2 x {unpacked, packed}
    assert rec["bit_exact"] is True
    assert rec["grid"]["packings"] == [False, True]
    by_id = {p["point_id"]: p for p in rec["points"]}
    assert set(by_id) == {"pe1_simd1", "pe1_simd16",
                          "pe1_simd1_packed", "pe1_simd16_packed"}
    for pid in ("pe1_simd1", "pe1_simd16"):
        plain, packed = by_id[pid], by_id[pid + "_packed"]
        assert not plain["packed"] and packed["packed"]
        assert packed["weight_bytes"] < plain["weight_bytes"]
        assert all(n["packed"] for n in packed["nodes"])
    assert rec["packed_points"] == 2
    # a packed point always survives: only another packed point can match
    # the strictly-smaller weight_bytes objective, and dominance among the
    # packed twins leaves the dominator on the frontier
    assert rec["packed_pareto_points"] >= 1
    assert "packed_pareto_points" in rec["floor_only"]
    assert rec["min_packed_pareto_points"] == 1
    assert "weight_bytes" in PARETO_MINIMIZE


def test_explore_record_round_trips_and_is_json_clean(small_sweep):
    path = small_sweep["path"]
    loaded = load_record(path)
    assert "path" not in loaded  # runtime-only key stays out of the file
    drop = {k: v for k, v in small_sweep.items() if k != "path"}
    assert loaded == json.loads(json.dumps(drop))  # JSON-clean, lossless
    assert loaded["grid"]["layers"][0]["name"] == "fc0.mvu"
    assert loaded["points"][0]["foldings"]  # [[pe, simd], ...] survived
