"""Per-architecture smoke tests (reduced configs) + decode consistency."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models.model import build


def _batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = (
            jax.random.normal(key, (b, 8, cfg.d_model)) * 0.1
        ).astype(jnp.bfloat16)
    if cfg.encdec:
        batch["enc_embeds"] = jax.random.normal(key, (b, 12, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_reduced(arch)
    m = build(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    batch = _batch(cfg, key)
    (loss, metrics), grads = jax.value_and_grad(m.loss, has_aux=True)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert loss.shape == ()
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in leaves), (
        f"{arch}: non-finite grads"
    )
    # reasonable initial loss ~ ln(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_full_config_instantiates(arch):
    cfg = get_config(arch)
    # full configs are exercised via abstract shapes only (no allocation)
    m = build(cfg)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    assert n_params > 0
    # config param-count model within 25% of actual instantiated count
    approx = cfg.param_count
    assert abs(approx - n_params) / n_params < 0.25, (arch, approx, n_params)


@pytest.mark.parametrize(
    "arch",
    ["yi-9b", "h2o-danube-1.8b", "granite-moe-3b-a800m", "mamba2-780m",
     "jamba-1.5-large-398b", "whisper-tiny"],
)
def test_prefill_decode_matches_forward(arch):
    """prefill(prompt) + decode steps == teacher-forced full forward logits."""
    # capacity_factor high enough that no token is ever dropped: capacity
    # MoE only matches step-decode exactly when routing drops nothing.
    cfg = get_reduced(arch).replace(remat=False, dtype="float32",
                                    capacity_factor=8.0)
    m = build(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    b, s_total, s_prompt = 2, 12, 8
    batch = _batch(cfg, key, b, s_total)
    tokens = batch["tokens"][:, : s_total + 1]

    # teacher-forced logits over the whole sequence via the loss path graph:
    # reuse internal pieces -- run prefill over the full sequence instead.
    state_full = m.init_decode_state(b, 32)
    pf_batch = {**batch, "tokens": tokens[:, :s_total]}
    logits_full, _ = m.prefill(params, pf_batch, state_full)

    # prompt prefill + step-by-step decode to the same position
    state = m.init_decode_state(b, 32)
    pr_batch = {**batch, "tokens": tokens[:, :s_prompt]}
    logits, state = m.prefill(params, pr_batch, state)
    for t in range(s_prompt, s_total):
        logits, state = m.decode_step(params, state, tokens[:, t])
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(logits_full), rtol=2e-2, atol=2e-2
    )
    # argmax agreement (the serving-relevant invariant)
    assert (np.argmax(np.asarray(logits), -1) == np.argmax(np.asarray(logits_full), -1)).all()


def test_tiny_lm_loss_decreases():
    cfg = get_reduced("yi-9b").replace(dtype="float32", remat=False)
    m = build(cfg)
    key = jax.random.PRNGKey(2)
    params = m.init(key)
    batch = _batch(cfg, key, b=4, s=24)

    @jax.jit
    def step(params, batch):
        (l, _), g = jax.value_and_grad(m.loss, has_aux=True)(params, batch)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg.astype(p.dtype), params, g)
        return params, l

    losses = []
    for _ in range(12):
        params, l = step(params, batch)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.5, losses


def test_mvu_backend_model_runs():
    """The paper's engine as the Linear backend of an assigned arch."""
    cfg = get_reduced("yi-9b").replace(linear_backend="mvu_w4a8", dtype="float32")
    m = build(cfg)
    key = jax.random.PRNGKey(3)
    params = m.init(key)
    batch = _batch(cfg, key)
    (loss, _), grads = jax.value_and_grad(m.loss, has_aux=True)(params, batch)
    assert jnp.isfinite(loss)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
