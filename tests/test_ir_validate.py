"""ir.validate_chain: every malformed-graph case fails with the offending
node's index/op and what the chain expected -- not a bare assert or an
index error from deep inside a transform."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import ir
from repro.core.ir import Node


def _input(shape=(8, 8, 3), bits=2):
    return Node("input", "in", {"shape": shape, "bits": bits})


def _conv(name="c0"):
    w = jnp.asarray(np.zeros((3, 3, 3, 4), np.float32))
    return Node("conv", name, {"kernel": 3, "stride": 1, "pad": 0}, {"w": w})


def _linear(name="fc0", n=4, k=16):
    return Node("linear", name, {}, {"w": jnp.zeros((n, k), jnp.float32)})


def test_empty_graph():
    with pytest.raises(ValueError, match="empty graph.*'input'"):
        ir.validate_chain([])


def test_head_must_be_input():
    with pytest.raises(ValueError,
                       match=r"must start with an 'input' node.*node 0 "
                             r"\(conv 'c0'\)"):
        ir.validate_chain([_conv()])


def test_unknown_op_names_index_and_node():
    g = [_input((16,)), Node("relu", "act0", {})]
    with pytest.raises(ValueError, match=r"node 1 \(relu 'act0'\): unknown op"):
        ir.validate_chain(g)


def test_input_only_legal_at_head():
    g = [_input((16,)), _linear(k=16), _input((16,))]
    with pytest.raises(ValueError,
                       match=r"node 2 \(input 'in'\).*only legal at index 0.*"
                             r"'linear'"):
        ir.validate_chain(g)


def test_spatial_op_after_flat_producer():
    g = [_input((8, 8, 3)), Node("flatten", "flat", {}),
         Node("maxpool", "pool", {"size": 2})]
    with pytest.raises(ValueError,
                       match=r"node 2 \(maxpool 'pool'\).*spatial \(H, W, C\) "
                             r"activation.*'flatten' \('flat', index 1\) "
                             r"yields shape \(192,\)"):
        ir.validate_chain(g)


def test_conv_after_linear_producer():
    g = [_input((16,)), _linear(k=16), _conv("c1")]
    with pytest.raises(ValueError,
                       match=r"node 2 \(conv 'c1'\).*producer 'linear'"):
        ir.validate_chain(g)


def test_swu_must_feed_mvu():
    swu = Node("swu", "c0.swu", {"kernel": 3, "stride": 1, "pad": 0})
    g = [_input(), swu, Node("batchnorm", "bn0", {}, {})]
    with pytest.raises(ValueError,
                       match=r"node 2 \(batchnorm 'bn0'\).*sliding-window "
                             r"unit must feed an 'mvu'"):
        ir.validate_chain(g)


def test_swu_cannot_terminate_the_chain():
    swu = Node("swu", "c0.swu", {"kernel": 3, "stride": 1, "pad": 0})
    with pytest.raises(ValueError, match=r"node 1 \(swu 'c0.swu'\).*cannot "
                                         r"terminate"):
        ir.validate_chain([_input(), swu])


def test_missing_param_or_attr_names_the_node():
    """A node without its op's required param/attr must fail as a named
    ValueError, not a bare KeyError from inside shape propagation."""
    g = [_input((16,)), Node("linear", "fc0", {})]  # no weight param
    with pytest.raises(ValueError,
                       match=r"node 1 \(linear 'fc0'\): missing required "
                             r"attr/param 'w'"):
        ir.validate_chain(g)
    g = [_input(), Node("conv", "c0", {}, {"w": jnp.zeros((3, 3, 3, 4))})]
    with pytest.raises(ValueError,
                       match=r"node 1 \(conv 'c0'\): missing required "
                             r"attr/param 'kernel'"):
        ir.validate_chain(g)


def test_well_formed_chains_pass():
    flat = [_input((16,)), _linear(k=16), Node("quant_act", "a", {"bits": 2})]
    ir.validate_chain(flat)
    spatial = [_input(), _conv(), Node("maxpool", "p", {"size": 2}),
               Node("flatten", "flat", {}), _linear(n=4, k=36)]
    ir.validate_chain(spatial)
