"""ir.validate_graph: every malformed-graph case fails with the offending
node's id (name) and op and what the graph expected -- not a bare assert
or a KeyError from deep inside a transform.  Also covers the deprecated
chain-era entry points (``validate_chain``, ``propagate(shape, node)``),
which must keep working behind one-warning-per-process shims."""

import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import ir
from repro.core.ir import Node


def _input(shape=(8, 8, 3), bits=2, name="in"):
    return Node("input", name, {"shape": shape, "bits": bits})


def _conv(name="c0"):
    w = jnp.asarray(np.zeros((3, 3, 3, 4), np.float32))
    return Node("conv", name, {"kernel": 3, "stride": 1, "pad": 0}, {"w": w})


def _linear(name="fc0", n=4, k=16):
    return Node("linear", name, {}, {"w": jnp.zeros((n, k), jnp.float32)})


def test_empty_graph():
    with pytest.raises(ValueError, match="empty graph.*'input'"):
        ir.validate_graph([])


def test_source_must_be_an_input_node():
    # a lone conv becomes a zero-input source, which only 'input' may be
    with pytest.raises(ValueError,
                       match=r"node 'c0' \(conv\): 'conv' takes exactly 1 "
                             r"input, got 0"):
        ir.validate_graph([_conv()])


def test_unknown_op_names_the_node():
    g = [_input((16,)), Node("relu", "act0", {})]
    with pytest.raises(ValueError, match=r"node 'act0' \(relu\): unknown op"):
        ir.validate_graph(g)


def test_duplicate_node_names():
    g = [_input((16,)), _linear("fc0", k=16), _linear("fc0", k=4)]
    with pytest.raises(ValueError,
                       match=r"node 'fc0' \(linear\): duplicate node name"):
        ir.validate_graph(g)


def test_input_takes_no_edges():
    g = [_input((16,)), _linear(k=16),
         Node("input", "in2", {"shape": (16,)}, inputs=("fc0",))]
    with pytest.raises(ValueError,
                       match=r"node 'in2' \(input\).*takes no inputs.*"
                             r"mid-chain 'input' is illegal"):
        ir.validate_graph(g)


def test_spatial_op_after_flat_producer():
    g = [_input((8, 8, 3)), Node("flatten", "flat", {}),
         Node("maxpool", "pool", {"size": 2})]
    with pytest.raises(ValueError,
                       match=r"node 'pool' \(maxpool\).*spatial \(H, W, C\) "
                             r"activation.*'flatten' \('flat'\) "
                             r"yields shape \(192,\)"):
        ir.validate_graph(g)


def test_conv_after_linear_producer():
    g = [_input((16,)), _linear(k=16), _conv("c1")]
    with pytest.raises(ValueError,
                       match=r"node 'c1' \(conv\).*producer 'linear'"):
        ir.validate_graph(g)


def test_swu_must_feed_mvu():
    swu = Node("swu", "c0.swu", {"kernel": 3, "stride": 1, "pad": 0})
    g = [_input(), swu, Node("batchnorm", "bn0", {}, {})]
    with pytest.raises(ValueError,
                       match=r"node 'bn0' \(batchnorm\).*sliding-window "
                             r"unit must feed an 'mvu'"):
        ir.validate_graph(g)


def test_swu_cannot_terminate_the_graph():
    swu = Node("swu", "c0.swu", {"kernel": 3, "stride": 1, "pad": 0})
    with pytest.raises(ValueError, match=r"node 'c0.swu' \(swu\).*cannot "
                                         r"terminate"):
        ir.validate_graph([_input(), swu])


def test_missing_param_or_attr_names_the_node():
    """A node without its op's required param/attr must fail as a named
    ValueError, not a bare KeyError from inside shape propagation."""
    g = [_input((16,)), Node("linear", "fc0", {})]  # no weight param
    with pytest.raises(ValueError,
                       match=r"node 'fc0' \(linear\): missing required "
                             r"attr/param 'w'"):
        ir.validate_graph(g)
    g = [_input(), Node("conv", "c0", {}, {"w": jnp.zeros((3, 3, 3, 4))})]
    with pytest.raises(ValueError,
                       match=r"node 'c0' \(conv\): missing required "
                             r"attr/param 'kernel'"):
        ir.validate_graph(g)


def test_well_formed_chains_pass():
    flat = [_input((16,)), _linear(k=16), Node("quant_act", "a", {"bits": 2})]
    ir.validate_graph(flat)
    spatial = [_input(), _conv(), Node("maxpool", "p", {"size": 2}),
               Node("flatten", "flat", {}), _linear(n=4, k=36)]
    ir.validate_graph(spatial)


# ------------------------------------------------- deprecated entry points
def test_validate_chain_is_a_warn_once_shim(monkeypatch):
    """validate_chain still validates (through validate_graph) but warns
    exactly once per process, like the EngineServer shim."""
    monkeypatch.setattr(ir, "_VALIDATE_CHAIN_WARNED", False)
    flat = [_input((16,)), _linear(k=16)]
    with pytest.warns(DeprecationWarning, match="validate_graph"):
        ir.validate_chain(flat)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a second warning would raise
        ir.validate_chain(flat)
    with pytest.raises(ValueError, match=r"node 'act0' \(relu\): unknown op"):
        ir.validate_chain([_input((16,)), Node("relu", "act0", {})])


def test_propagate_legacy_signature_shim(monkeypatch):
    """The chain-era ``propagate(shape, node)`` convention keeps working
    (one DeprecationWarning per process) and matches the new signature."""
    monkeypatch.setattr(ir, "_PROPAGATE_SHIM_WARNED", False)
    node = _linear(n=4, k=16)
    with pytest.warns(DeprecationWarning,
                      match=r"propagate\(node, \*input_shapes\)"):
        assert ir.propagate((16,), node) == (4,)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second legacy call: silent
        assert ir.propagate((16,), node) == (4,)
        assert ir.propagate(None, _input((16,))) == (16,)
    assert ir.propagate(node, (16,)) == (4,)  # new signature, no warning
