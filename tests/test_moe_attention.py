import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import attention as attn
from repro.models import moe as moe_mod


# ----------------------------------------------------------------- MoE
def test_dispatch_combine_capacity_and_weights():
    idx = jnp.asarray([[0, 1], [0, 1], [0, 2], [1, 2]])  # (G=4, k=2)
    w = jnp.full((4, 2), 0.5, jnp.float32)
    e, cap = 3, 2
    dispatch, combine = moe_mod.dispatch_combine(idx, w, e, cap)
    d = np.asarray(dispatch)
    # expert 0 receives tokens 0,1 (cap 2); token 2's expert-0 slot dropped
    assert d[:, 0].sum() == 2
    assert d[2, 0].sum() == 0  # dropped
    # every kept slot holds exactly one token
    assert (d.sum(0) <= 1.0 + 1e-6).all()
    c = np.asarray(combine)
    np.testing.assert_allclose(c[d > 0], 0.5)


def test_moe_ffn_output_matches_dense_eval_when_single_expert():
    """E=1 top-1 MoE (cap >= tokens) == plain FFN with that expert."""
    cfg = get_reduced("granite-moe-3b-a800m").replace(
        num_experts=1, num_experts_per_tok=1, capacity_factor=4.0,
        moe_group_size=16, dtype="float32",
    )
    key = jax.random.PRNGKey(0)
    p = moe_mod.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    out, aux = moe_mod.moe_ffn(p, cfg, x, group_size=16, capacity_factor=4.0)
    # dense evaluation of expert 0
    up = x @ p["w_up"][0]
    gate = x @ p["w_gate"][0]
    want = (jax.nn.silu(gate) * up) @ p["w_down"][0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-2, atol=2e-2)


def test_moe_router_gradients_flow():
    cfg = get_reduced("qwen3-moe-235b-a22b").replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    p = moe_mod.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model)) * 0.3

    def f(p):
        out, aux = moe_mod.moe_ffn(p, cfg, x, group_size=64)
        return jnp.sum(out**2) + 0.01 * aux

    g = jax.grad(f)(p)
    assert float(jnp.sum(jnp.abs(g["router"]["w"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_up"]))) > 0


# ------------------------------------------------------------- attention
def test_gqa_matches_full_mha_when_kv_equals_heads():
    cfg = get_reduced("yi-9b").replace(num_kv_heads=4, num_heads=4, dtype="float32")
    key = jax.random.PRNGKey(0)
    p = attn.attn_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(10)[None], (2, 10))
    out = attn.attention(p, cfg, x, pos)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_causal_masking():
    """Future tokens must not affect past outputs."""
    cfg = get_reduced("yi-9b").replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    p = attn.attn_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(12)[None], (1, 12))
    y1 = attn.attention(p, cfg, x, pos)
    x2 = x.at[:, 8:].set(7.0)
    y2 = attn.attention(p, cfg, x2, pos)
    np.testing.assert_allclose(np.asarray(y1[:, :8]), np.asarray(y2[:, :8]),
                               rtol=1e-4, atol=1e-5)


def test_sliding_window_restricts_context():
    cfg = get_reduced("h2o-danube-1.8b").replace(window=4, dtype="float32")
    key = jax.random.PRNGKey(0)
    p = attn.attn_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(16)[None], (1, 16))
    y1 = attn.attention(p, cfg, x, pos)
    # perturbing a token > window steps back must not change the output
    x2 = x.at[:, 0].set(9.0)
    y2 = attn.attention(p, cfg, x2, pos)
    np.testing.assert_allclose(np.asarray(y1[:, 8:]), np.asarray(y2[:, 8:]),
                               rtol=1e-4, atol=1e-5)
    # but it does change outputs inside the window
    assert not np.allclose(np.asarray(y1[:, 1]), np.asarray(y2[:, 1]), atol=1e-5)


def test_mrope_text_degenerates_to_rope():
    from repro.models.layers import apply_mrope, apply_rope

    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 6))
    a = apply_rope(x, pos, theta=1e6)
    b = apply_mrope(x, pos3, theta=1e6, sections=(6, 5, 5))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_chunked_attention_matches_naive():
    """Query-chunked exact attention == naive, causal + SWA + GQA."""
    for arch, window in [("yi-9b", None), ("h2o-danube-1.8b", 8)]:
        cfg = get_reduced(arch).replace(dtype="float32", attn_q_chunk=0)
        if window:
            cfg = cfg.replace(window=window)
        p = attn.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
        y_naive = attn.attention(p, cfg, x, pos)
        y_chunk = attn.attention(p, cfg.replace(attn_q_chunk=16), x, pos)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                                   rtol=1e-5, atol=1e-6)
        # gradients flow through the chunk scan
        g = jax.grad(lambda xx: jnp.sum(
            attn.attention(p, cfg.replace(attn_q_chunk=16), xx, pos) ** 2))(x)
        assert bool(jnp.all(jnp.isfinite(g)))
