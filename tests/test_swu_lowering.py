import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dataflow, lowering, swu
from repro.core.ir import Graph, Node


@pytest.mark.parametrize("kd,stride,pad", [(3, 1, 0), (4, 2, 1), (5, 1, 2)])
def test_swu_matches_lax_conv(kd, stride, pad):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2, 12, 12, 5)).astype(np.float32)
    w = rng.normal(size=(kd, kd, 5, 7)).astype(np.float32)
    got = swu.conv_via_swu_mvu(jnp.asarray(x), jnp.asarray(w), stride, pad)
    want = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(w),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def _mlp_graph(rng, dims, bits=2):
    g: Graph = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        g.append(Node("batchnorm", f"bn{i}", {}, {
            "gamma": jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32)),
            "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
            "mean": jnp.asarray(rng.normal(0, 2, n).astype(np.float32)),
            "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
        }))
        g.append(Node("quant_act", f"act{i}", {"bits": bits, "act_scale": 1.0}))
    return g


def test_streamlined_mlp_matches_float_reference():
    """Lower+streamline an MLP; integer MVU execution == quant(BN(x W^T))."""
    rng = np.random.default_rng(42)
    dims = [24, 16, 8]
    bits = 2
    g = _mlp_graph(rng, dims, bits)
    lowered = lowering.lower_to_mvu(g, mode="standard", weight_bits=4, act_bits=bits)
    stream = lowering.streamline(lowered)
    stream = lowering.finalize(stream)
    stream = lowering.apply_folding(stream, max_pe=8, max_simd=8)

    x = rng.integers(0, 2**bits, (5, dims[0])).astype(np.int32)
    got = np.asarray(dataflow.execute(stream, jnp.asarray(x)))

    # float reference with the same quantized weights
    cur = x.astype(np.float64)
    mvu_nodes = [n for n in stream if n.op == "mvu"]
    lin_nodes = [n for n in g if n.op == "linear"]
    bn_nodes = [n for n in g if n.op == "batchnorm"]
    for i in range(len(lin_nodes)):
        wq = np.asarray(mvu_nodes[i].params["mvu"].weights).astype(np.float64)
        # recover the real weight grid: int rows were sign-streamlined, so
        # reconstruct BN on acc_int with flipped gammas equivalently by
        # following the integer pipeline exactly:
        acc = cur @ wq.T
        # integer thresholds applied to integer acc
        t = np.asarray(mvu_nodes[i].params["mvu"].thresholds)
        cur = (acc[..., None] >= t[None]).sum(-1).astype(np.float64)
    np.testing.assert_array_equal(got, cur.astype(np.int32))
    assert got.min() >= 0 and got.max() <= 2**bits - 1


def test_streamline_thresholds_equal_bn_quant_semantics():
    """End-to-end: integer pipeline == quant(BN(x @ Wq^T * scale)) per layer."""
    rng = np.random.default_rng(7)
    dims = [12, 6]
    bits = 3
    g = _mlp_graph(rng, dims, bits)
    lowered = lowering.lower_to_mvu(g, mode="standard", weight_bits=4, act_bits=bits)
    stream = lowering.finalize(lowering.streamline(lowered))

    x = rng.integers(0, 2**bits, (64, dims[0])).astype(np.int32)
    got = np.asarray(dataflow.execute(stream, jnp.asarray(x)))

    # independent float model: quantize weights the same way, run BN+quant
    from repro.core.quantize import quantize_weights
    w = np.asarray(g[1].params["w"])
    qt = quantize_weights(jnp.asarray(w), 4)
    wr = np.asarray(qt.values).astype(np.float64) * np.asarray(qt.scale)
    bn = g[2].params
    acc = x.astype(np.float64) @ wr.T
    y = (acc - np.asarray(bn["mean"])) * np.asarray(bn["gamma"]) / np.sqrt(
        np.asarray(bn["var"]) + 1e-5) + np.asarray(bn["beta"])
    want = np.clip(np.round(y), 0, 2**bits - 1).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_conv_graph_lowering_and_schedule():
    rng = np.random.default_rng(3)
    g: Graph = [Node("input", "in", {"shape": (8, 8, 4), "bits": 4})]
    w = rng.normal(0, 0.5, (3, 3, 4, 8)).astype(np.float32)
    g.append(Node("conv", "c0", {"kernel": 3, "stride": 1, "pad": 0},
                  {"w": jnp.asarray(w)}))
    lowered = lowering.lower_to_mvu(g, mode="standard", weight_bits=4)
    assert [n.op for n in lowered] == ["input", "swu", "mvu"]
    lowered = lowering.finalize(lowered)
    lowered = lowering.apply_folding(lowered, max_pe=8, max_simd=9)
    sched = dataflow.schedule(lowered)
    assert len(sched.stages) == 1
    st = sched.stages[0]
    # 6x6 output pixels, N=8, K=36
    fold = lowered[2].attrs["config"].resolved_folding()
    assert st.cycles == 36 * (8 // fold.pe) * (36 // fold.simd)
    s = sched.summary()
    assert s["bottleneck"] == "c0.mvu"
