"""CNV (the FINN BNN convnet) streaming through the fused dataflow engine.

The conv quickstart: build the CNV topology (conv/conv/pool/.../dense),
lower conv layers to SWU+MVU pairs, and let ``FusedEngine`` collapse them
into line-buffer conv kernels -- the whole network runs as ONE jit'd
microbatch stream, bit-exact with the eager behavioural interpreter, and
the (B, OH*OW, Kd^2*C) im2col matrix never materializes.

Run:  PYTHONPATH=src python examples/cnv_dataflow.py
"""

import numpy as np
import jax.numpy as jnp

from repro.configs import cnv_bnn
from repro.core import dataflow, lowering
from repro.core.engine import FusedEngine


def main():
    spec = cnv_bnn.QUICK  # 1/8-channel CNV on 16x16 inputs; FULL = the real one
    graph = cnv_bnn.build_graph(spec, seed=0)
    lowered = lowering.lower_to_mvu(
        graph, mode="xnor", weight_bits=spec.weight_bits, act_bits=spec.act_bits)
    fin = lowering.apply_folding(lowering.finalize(lowered))

    engine = FusedEngine(fin)  # fuses bn/quant epilogues, then swu+mvu pairs
    ops_left = [n.op for n in engine.graph]
    print(f"[cnv] lowered ops: {ops_left}")
    print(f"[cnv] schedule: {engine.schedule.summary()}")

    rng = np.random.default_rng(1)
    x = jnp.asarray(
        rng.integers(0, 2**spec.act_bits, (32, spec.image, spec.image, 3)),
        jnp.int32)
    plan = engine.plan(x.shape[0])
    print(f"[cnv] stream plan: {plan.n_micro} microbatches of "
          f"{plan.microbatch} image(s), II = {plan.interval_cycles} cycles")

    logits = np.asarray(engine(x))
    want = np.asarray(dataflow.execute(fin, x))
    assert np.array_equal(logits, want), "engine diverged from interpreter"
    print(f"[cnv] logits {logits.shape}, bit-exact with dataflow.execute")
    print(f"[cnv] predictions: {logits.argmax(-1)[:10]} ...")
    print("OK: CNV streamed through the fused conv path")


if __name__ == "__main__":
    main()
