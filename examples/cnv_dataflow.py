"""CNV (the FINN BNN convnet) streaming through the fused dataflow engine.

The conv quickstart, now one ``repro.build`` call: build the CNV topology
(conv/conv/pool/.../dense), and let the step pipeline lower conv layers to
SWU+MVU pairs, rate-balance the folding, collapse the pairs into
line-buffer conv kernels, and compile the whole network as ONE jit'd
microbatch stream -- every transform verified bit-exact against the eager
behavioural interpreter, with the `(B, OH*OW, Kd^2*C)` im2col matrix never
materializing.  The BuildReport (per-step timing, per-stage folding +
resource estimates) lands in ``experiments/build/``.

Run:  PYTHONPATH=src python examples/cnv_dataflow.py
"""

import numpy as np
import jax.numpy as jnp

from repro.build import build
from repro.configs import cnv_bnn


def main():
    spec = cnv_bnn.QUICK  # 1/8-channel CNV on 16x16 inputs; FULL = the real one
    acc = build(
        cnv_bnn.build_graph(spec, seed=0),
        target="engine", mode="xnor",
        weight_bits=spec.weight_bits, act_bits=spec.act_bits,
        folding="balance", tune="cache",
        name="cnv_quick", output_dir="experiments/build",
    )
    engine = acc.engine
    print(f"[cnv] build steps: {' -> '.join(acc.report.step_names)}")
    print(f"[cnv] verified steps: "
          f"{[s.name for s in acc.report.steps if s.verified]}")
    print(f"[cnv] lowered ops: {[n.op for n in engine.graph]}")
    print(f"[cnv] schedule: {engine.schedule.summary()}")
    print(f"[cnv] per-stage folding: "
          f"{[(n.name, n.pe, n.simd, n.cycles) for n in acc.report.nodes]}")
    print(f"[cnv] build report -> {acc.report.path}")

    rng = np.random.default_rng(1)
    x = jnp.asarray(
        rng.integers(0, 2**spec.act_bits, (32, spec.image, spec.image, 3)),
        jnp.int32)
    plan = engine.plan(x.shape[0])
    print(f"[cnv] stream plan: {plan.n_micro} microbatches of "
          f"{plan.microbatch} image(s), II = {plan.interval_cycles} cycles")

    logits = np.asarray(engine(x))
    want = np.asarray(acc.interpret(x))
    assert np.array_equal(logits, want), "engine diverged from interpreter"
    print(f"[cnv] logits {logits.shape}, bit-exact with the reference interpreter")
    print(f"[cnv] predictions: {logits.argmax(-1)[:10]} ...")
    print("OK: CNV streamed through the fused conv path")


if __name__ == "__main__":
    main()
