"""The paper's full example (Section 6.5): network intrusion detection.

End-to-end FINN flow on the Table 6 MLP (600-64-64-64-1, 2-bit):

  1. train the float MLP with quantization-aware STE on a synthetic
     UNSW-NB15 stand-in (offline container; same feature/label geometry),
  2. compile it through the ``repro.build`` step pipeline (lowering,
     streamlining, the paper's Table 6 PE/SIMD folding, per-step
     verification against the reference interpreter),
  3. run integer inference through the Pallas MVU kernels and verify it
     matches the float teacher,
  4. print the dataflow schedule: per-layer cycles reproduce Table 7,
  5. serve the fused engine through the continuous batcher, and write the
     BuildReport JSON (the software analog of the paper's resource and
     synthesis-time tables).

Run:  PYTHONPATH=src python examples/nid_intrusion_detection.py [--fast]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.nid_mlp import PIPELINE_DEPTH, accuracy_check
from repro.configs import nid_mlp
from repro.core.folding import Folding
from repro.core.resource_model import mvu_resources


def main(fast: bool = False):
    print("== NID MLP (paper Table 6): 600-64-64-64-1 @ 2-bit ==")
    for i, (k, n, pe, simd) in enumerate(nid_mlp.LAYERS):
        fold = Folding(pe, simd)
        res = mvu_resources(n, k, fold, mode="standard", weight_bits=2,
                            act_bits=2, n_thresh=3)
        cycles = fold.cycles(n, k, 1) + PIPELINE_DEPTH
        paper = [17, 13, 13, 13][i]
        print(f"  layer {i}: K={k:4d} N={n:3d} PE={pe:3d} SIMD={simd:3d} "
              f"| cycles {cycles} (paper RTL: {paper}) "
              f"| wmem_depth={res.weight_mem_depth} inbuf={res.input_buffer_depth}")

    print("== train (QAT) -> build(streamline steps) -> integer inference ==")
    out = accuracy_check(steps=120 if fast else 300)
    print(f"  float teacher accuracy : {out['float_acc']:.3f}")
    print(f"  integer MVU accuracy   : {out['mvu_int_acc']:.3f}")
    print(f"  pipeline interval      : {out['pipeline_interval_cycles']} cycles "
          f"(bottleneck {out['bottleneck']})")
    print(f"  pipeline latency       : {out['pipeline_latency_cycles']} cycles")
    assert out["mvu_int_acc"] > 0.95, "integer pipeline must match the teacher"
    print("OK: end-to-end FINN flow reproduced on the NID use case")

    print("== repro.build: one call replaces the manual lowering chain ==")
    import numpy as np
    import jax.numpy as jnp

    from benchmarks.engine_throughput import nid_accelerator

    # target="serving" = the engine pipeline + measured cycle-time
    # calibration; every step is verified bit-exact against the reference
    # interpreter and the BuildReport lands next to the autotune cache.
    acc = nid_accelerator(target="serving", output_dir="experiments/build")
    engine = acc.engine
    plan = engine.plan(256)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 4, (256, 600)), jnp.int32)
    same = np.array_equal(np.asarray(engine(x)), np.asarray(acc.interpret(x)))
    print(f"  build steps            : {' -> '.join(acc.report.step_names)}")
    print(f"  verified steps         : "
          f"{sum(1 for s in acc.report.steps if s.verified)} "
          f"(bit-exact vs the reference interpreter, per transform)")
    print(f"  epilogues fused        : {sum(1 for n in engine.graph if n.attrs.get('fused'))} "
          f"bn+quant pairs -> MVU thresholds")
    print(f"  stream plan (B=256)    : {plan.n_micro} microbatches x {plan.microbatch} "
          f"(II {plan.interval_cycles} cycles)")
    print(f"  build report           : {acc.report.path}")
    print(f"  bit-exact vs interpret : {same}")
    assert same

    import warnings

    from repro.launch.serve import EngineServer

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)  # legacy shim
        server = EngineServer(engine, batch_buckets=(1, 8, 32))
    rids = [server.submit(np.asarray(x[i])) for i in range(11)]
    done = {r.rid: r for r in server.flush()}
    ok = all(np.array_equal(done[r].out, np.asarray(engine(x[:11]))[i])
             for i, r in enumerate(rids))
    print(f"  served 11 requests in {server.stats['flushes']} bucketed flushes "
          f"(padding {server.stats['padded_samples']}): correct={ok}")
    assert ok
    print("OK: fused engine serves the NID workload bit-exactly")

    print("== continuous-batching serving subsystem (Accelerator.serve) ==")
    batcher = acc.serve(batch_buckets=(1, 8, 32), slo_s=0.05)
    rids = [batcher.submit(np.asarray(x[i])) for i in range(11)]
    batcher.drain()
    ok = all(np.array_equal(batcher.pop_result(r).out,
                            np.asarray(engine(x[:11]))[i])
             for i, r in enumerate(rids))
    snap = batcher.metrics.snapshot()
    budget = batcher.budgets[batcher.bucket_for(1)]
    cal = acc.calibration
    print(f"  admission queue         : bounded at {batcher.queue.capacity} "
          f"samples, validated against input spec {batcher.spec.shape}")
    ii = engine.schedule.steady_state_interval
    print(f"  flush budget (bucket 1) : {budget * 1e3:.3f} ms "
          f"(II {ii} cycles x measured {cal['s_per_cycle'] * 1e6:.1f} us/cycle "
          f"x 2.0 safety)")
    print(f"  replicas                : {len(batcher.pool)} device(s), "
          f"least-loaded async dispatch")
    print(f"  metrics snapshot        : p99 {snap['p99_ms']:.2f} ms, "
          f"{snap['flushes']} flushes, padding {snap['padding_overhead']:.0%}, "
          f"correct={ok}")
    assert ok
    print("OK: continuous batcher serves the NID workload bit-exactly")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="fewer QAT steps (CI smoke)")
    main(fast=ap.parse_args().fast)
