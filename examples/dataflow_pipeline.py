"""FINN streaming dataflow on a TPU mesh: the pipeline-parallel executor.

FINN instantiates one MVU per layer and streams activations through AXI
links (paper Fig. 6).  This example runs the same discipline on a device
mesh: four pipeline stages (one per device), microbatches streaming through
ppermute links, and the FINN folding pass rate-balancing the stages.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/dataflow_pipeline.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.folding import balance_pipeline
from repro.distributed.pipeline import (
    pipeline_apply,
    sequential_reference,
    stage_params_split,
)


def main():
    n_dev = len(jax.devices())
    stages = 4 if n_dev >= 4 else n_dev
    L, d = 8, 64
    n_micro, mb = 8, 4

    key = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(key, (L, d, d)) * (1.0 / np.sqrt(d)),
        "b": jnp.zeros((L, d)),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

    def layer_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    # FINN folding: rate-balance the (identical) layers -> equal stage cycles
    folds = balance_pipeline([(d, d, 1)] * L, max_pe=64, max_simd=64)
    cycles = [f.cycles(d, d) for f in folds]
    print(f"[dataflow] {L} layers on {stages} stages; per-layer cycles "
          f"{cycles[0]} (balanced: {len(set(cycles)) == 1})")
    print(f"[dataflow] steady-state interval = {max(cycles)} cycles, "
          f"fill/drain bubbles = {stages - 1} microbatch ticks")

    mesh = jax.make_mesh((stages,), ("stage",))
    out = pipeline_apply(layer_fn, stage_params_split(params, stages), x, mesh)
    want = sequential_reference(layer_fn, params, x)
    err = float(jnp.abs(out - want).max())
    print(f"[dataflow] pipeline output == sequential reference "
          f"(max err {err:.2e})")
    assert err < 1e-5
    print("OK: FINN dataflow schedule reproduced with ppermute streams")


if __name__ == "__main__":
    main()
