"""Quickstart: the paper's MVU in five minutes.

1. Build a quantized MVU layer (three SIMD datapaths).
2. Run the Pallas kernels against the XLA reference (bit-exact).
3. Fold a BatchNorm+quantizer into integer thresholds (streamlining).
4. Use the FINN-style folding pass + resource model.
5. Compile a whole MLP chain with the ``repro.build`` step pipeline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.folding import Folding, choose_folding
from repro.core.mvu import MVUConfig, MVULayer
from repro.core.thresholds import bn_quant_thresholds, integerize_thresholds
from repro.kernels import ops, packing


def main():
    key = jax.random.PRNGKey(0)
    m, n, k = 64, 64, 256

    print("== 1. three SIMD datapaths (paper Fig. 4) ==")
    for mode in ("xnor", "binary", "standard"):
        cfg = MVUConfig(in_features=k, out_features=n, mode=mode,
                        folding=Folding(32, 32))
        layer = MVULayer(cfg)
        params = layer.init_params(key)
        if mode == "xnor":
            x = packing.pack_bits(
                jax.random.bernoulli(key, 0.5, (m, k)).astype(jnp.int32))
        else:
            x = jax.random.randint(key, (m, k), -8, 8, jnp.int8)
        y = layer(params, x)
        res = layer.resources()
        print(f"  {mode:9s} out={y.shape} {y.dtype} | "
              f"cycles/pixel={res.cycles} wmem_depth={res.weight_mem_depth} "
              f"inbuf_depth={res.input_buffer_depth}")

    print("== 2. Pallas kernel == XLA reference (bit exact) ==")
    a = jax.random.randint(key, (37, 300), -8, 8, jnp.int8)
    w = jax.random.randint(jax.random.PRNGKey(1), (53, 300), -8, 8, jnp.int8)
    via_pallas = ops.mvu(a, w, "standard", block_m=32, block_n=32, block_k=64)
    via_xla = ops.mvu(a, w, "standard", backend="xla")
    assert (np.asarray(via_pallas) == np.asarray(via_xla)).all()
    print(f"  exact match on {via_pallas.shape}")

    print("== 3. BN+quant -> integer thresholds (streamlining) ==")
    gamma, beta = jnp.ones((4,)), jnp.zeros((4,))
    mean, var = jnp.zeros((4,)), jnp.ones((4,)) - 1e-5
    t, flip = bn_quant_thresholds(gamma, beta, mean, var, bits=2)
    print(f"  thresholds (2-bit):\n{integerize_thresholds(t)}")

    print("== 4. folding pass (FINN 'Folding and Resource Estimation') ==")
    fold = choose_folding(64, 600, target_cycles=16)
    print(f"  N=64 K=600 target 16 cycles -> PE={fold.pe} SIMD={fold.simd} "
          f"cycles={fold.cycles(64, 600)}")

    print("== 5. the build pipeline (FINN build_dataflow analog) ==")
    from repro.build import build, default_steps
    from repro.core.ir import Node

    rng = np.random.default_rng(0)
    g = [Node("input", "in", {"shape": (64,), "bits": 2})]
    for i, (kk, nn) in enumerate(((64, 32), (32, 8))):
        g.append(Node("linear", f"fc{i}", {},
                      {"w": jnp.asarray(rng.normal(0, 0.5, (nn, kk)),
                                        jnp.float32)}))
        if i == 0:
            g.append(Node("quant_act", "act0", {"bits": 2, "act_scale": 1.0}))
    acc = build(g, target="engine", mode="standard", weight_bits=4, act_bits=2)
    xb = jnp.asarray(rng.integers(0, 4, (16, 64)), jnp.int32)
    assert np.array_equal(np.asarray(acc(xb)), np.asarray(acc.interpret(xb)))
    print(f"  default steps ('engine'): {' -> '.join(default_steps('engine'))}")
    print(f"  verified transforms     : "
          f"{[s.name for s in acc.report.steps if s.verified]}")
    print(f"  schedule                : {acc.report.schedule}")
    print("  engine == interpreter on a probe batch (verified per step)")


if __name__ == "__main__":
    main()
