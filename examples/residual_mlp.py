"""Residual (skip-connection) MLP: the DAG IR end-to-end.

The chain-era IR could only express straight-line models; this example
exercises everything the DAG lift added, on a NID-style variant with a
residual connection around the middle layer:

      in(600) -> fc0 -> bn0 -> act0 --+--> fc1 -> bn1 -> act1 --+
                                      |                         v
                                      +-----------------------> add("res")
                                                                 |
                                                                 v
                                                             fc2 -> out(1)

  1. author the fan-out/fan-in graph (``repro.configs.residual_mlp``),
  2. validate it (``ir.validate_graph``: arity, broadcast, single sink),
  3. build it for all three targets -- interpret, engine, pipeline --
     through the ``repro.build`` step pipeline with every verification
     hook on, each transform held bit-exact against the DAG interpreter,
  4. print the lowered topology: edge list, branch labels, and the
     join's branch-latency skew + FIFO depth from the dataflow schedule,
  5. write the BuildReport JSON (now carrying ``edges`` and per-node
     ``inputs``/``branch``) next to the other committed reports.

Run:  PYTHONPATH=src python examples/residual_mlp.py [--fast]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import jax.numpy as jnp

from repro.build import build
from repro.configs import residual_mlp
from repro.core import ir


def main(fast: bool = False):
    batch = 64 if fast else 256
    graph = residual_mlp.build_graph()
    print("== residual NID-MLP variant: 600-64-(64+skip)-1 @ 2-bit ==")
    ir.validate_graph(graph)
    labels = ir.branch_labels(graph)
    for node, ins, out_shape in ir.io_shapes(graph):
        srcs = ", ".join(node.inputs) if node.inputs else "-"
        print(f"  {node.name:5s} ({node.op:9s}) <- {srcs:12s} "
              f"-> {out_shape}  [branch {labels[node.name]}]")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 2**residual_mlp.INPUT_BITS,
                                 (batch, residual_mlp.LAYERS[0][0])),
                    jnp.int32)

    print("== repro.build: same graph, three targets, all verified ==")
    accs = {}
    for target in ("interpret", "engine", "pipeline"):
        # the engine build writes the committed BuildReport artifact
        out_dir = "experiments/build" if target == "engine" else None
        accs[target] = build(graph, target=target, mode="standard",
                             weight_bits=residual_mlp.WEIGHT_BITS,
                             act_bits=residual_mlp.INPUT_BITS,
                             folding=residual_mlp.foldings(),
                             name="residual_mlp", output_dir=out_dir)
        rep = accs[target].report
        print(f"  target {target:9s}: steps {' -> '.join(rep.step_names)} "
              f"| verified {sum(1 for s in rep.steps if s.verified)}")

    ref = np.asarray(accs["interpret"](x))
    for target in ("engine", "pipeline"):
        got = np.asarray(accs[target](x))
        same = np.array_equal(got, ref)
        print(f"  {target:9s} vs interpret: bit-exact={same}")
        assert same, f"{target} diverged from the DAG reference interpreter"

    acc = accs["engine"]
    rep = acc.report
    print("== lowered DAG topology (from the BuildReport) ==")
    print(f"  edges          : {['->'.join(e) for e in rep.edges]}")
    print(f"  node branches  : "
          f"{ {n.name: n.branch for n in rep.nodes} }")
    sched = acc.engine.schedule
    print(f"  interval       : {sched.steady_state_interval} cycles "
          f"(bottleneck {sched.bottleneck.name})")
    print(f"  critical path  : {sched.latency_cycles} cycles "
          f"(longest path, not the stage sum)")
    for j in sched.joins:
        skew = max(j.branch_latency) - min(j.branch_latency)
        print(f"  join {j.name!r}     : branches {j.branches}, "
              f"latencies {j.branch_latency} (skew {skew}) "
              f"-> FIFO depth {j.fifo_depth}")
    assert sched.joins and sched.joins[0].fifo_depth >= 2
    print(f"  build report   : {rep.path}")
    print("OK: skip-connection graph builds and streams bit-exactly "
          "on every target")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smaller probe batch (CI smoke)")
    main(fast=ap.parse_args().fast)
