"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
a host-device mesh, with checkpointing + resume + the full sharded train
step (same code path the 256/512-chip dry-run lowers).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import os
import time

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--quant", default=None,
                    help="mvu_w8a8|mvu_w4a8: route projections through the "
                         "paper's MVU datapath (QAT fake-quant)")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import train_loop
    from repro.models.model import build
    from repro.optim import adamw

    # ~100M params: yi-9b family scaled down (8 layers, d=768)
    cfg = get_config("yi-9b").replace(
        name="yi-100m", num_layers=8, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        dtype="float32", remat=False,
    )
    if args.quant:
        cfg = cfg.replace(linear_backend=args.quant)
    model = build(cfg)
    n_params = cfg.param_count
    print(f"[train_lm] {cfg.name}: ~{n_params/1e6:.0f}M params, "
          f"{n_dev} devices, quant={args.quant}")

    shape = {1: (1, 1), 2: (2, 1), 4: (2, 2), 8: (4, 2)}.get(n_dev, (n_dev, 1))
    mesh = make_host_mesh(shape)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    t0 = time.time()
    _, _, hist = train_loop(
        model, mesh, steps=args.steps, batch_iter=iter(data),
        opt_cfg=adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 10),
    )
    data.close()
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"[train_lm] loss {hist[0]:.3f} -> {hist[-1]:.3f} in {dt:.0f}s "
          f"({toks/dt:.0f} tok/s); ckpts in {args.ckpt_dir}")
    if args.steps >= 100:
        assert hist[-1] < hist[0] - 1.0, "loss should drop by >1 nat on synthetic LM"


if __name__ == "__main__":
    main()
