"""Serve a small LM with batched requests: prefill + lockstep decode,
optionally through the paper's integer MVU datapath (post-training W8A8).

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 8 --quant mvu_w8a8
"""

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--quant", default=None, help="mvu_w8a8: integer serving")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.serve import Request, serve_loop
    from repro.models.layers import quantize_model_params
    from repro.models.model import build

    cfg = get_config("yi-9b").replace(
        name="yi-serve", num_layers=4, d_model=256, num_heads=8,
        num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=1000,
        dtype="float32", remat=False,
    )
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.quant:
        # post-training quantization: every projection -> integer MVU params
        cfg = cfg.replace(linear_backend=args.quant)
        model = build(cfg)

        params = quantize_model_params(params, args.quant)
        print(f"[serve_lm] weights quantized to {args.quant} (integer MVU datapath)")

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, rng.integers(4, 12)),
                max_new=args.max_new, t_submit=time.time())
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = serve_loop(model, params, reqs, batch=args.batch, max_len=64)
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"[serve_lm] served {len(done)} requests, {total_new} tokens "
          f"in {dt:.1f}s ({total_new/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt={r.prompt[:6].tolist()}... -> {r.out[:8]}")
    assert all(len(r.out) == args.max_new for r in done)


if __name__ == "__main__":
    main()
